"""Validate the trip-count-aware HLO cost analyzer (launch/hlo_cost.py).

Ground truth: ``compiled.cost_analysis()`` on UNROLLED programs (where
XLA's numbers are trustworthy).  The analyzer must (a) match those within
tolerance, and (b) produce the same numbers from the SCANNED variant of
the same program — the whole point of the module.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _cost_official(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):     # older JAX: one dict per device
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _cost_mine(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    mc = hlo_cost.analyze_text(c.as_text())
    return mc.flops, mc.bytes


def test_dot_flops_exact():
    x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    fn = lambda a, b: a @ b
    off, _ = _cost_official(fn, x, w)
    mine, _ = _cost_mine(fn, x, w)
    assert off == 2 * 64 * 256 * 128
    assert mine == off


def test_batched_dot_flops():
    x = jax.ShapeDtypeStruct((4, 64, 256), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((4, 256, 128), jnp.bfloat16)
    fn = lambda a, b: jnp.einsum("bik,bkj->bij", a, b)
    off, _ = _cost_official(fn, x, w)
    mine, _ = _cost_mine(fn, x, w)
    # official additionally counts bf16<->f32 convert ops at 1 flop/elem
    assert mine == pytest.approx(off, rel=0.02)


def test_scan_equals_unrolled():
    """The core property: scanned-program cost == unrolled-program cost."""
    T = 12

    def body(c, w):
        return jnp.tanh(c @ w), ()

    def scanned(x, ws):
        c, _ = jax.lax.scan(body, x, ws)
        return c

    def unrolled(x, ws):
        for i in range(T):
            x = jnp.tanh(x @ ws[i])
        return x

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, 128, 128), jnp.float32)

    off_unrolled, off_bytes = _cost_official(unrolled, x, ws)
    mine_scanned, mine_bytes = _cost_mine(scanned, x, ws)
    mine_unrolled, _ = _cost_mine(unrolled, x, ws)

    # official on scanned would be ~T x too small; ours must match unrolled
    assert mine_scanned == pytest.approx(off_unrolled, rel=0.05)
    assert mine_unrolled == pytest.approx(off_unrolled, rel=0.05)
    # bytes: each iteration reads one (128,128) slice + carry + writes carry.
    # official unrolled reads all T slices once: ws + T*(carry io).  Ours
    # (scanned, slice-aware fusion bytes) must be within 2x of official.
    assert mine_bytes == pytest.approx(off_bytes, rel=1.0)


def test_nested_scan():
    To, Ti = 5, 7

    def inner(c, w):
        return c * w + 1.0, ()

    def outer(c, ws):
        c2, _ = jax.lax.scan(inner, c, ws)
        return c2, ()

    def fn(x, ws):
        c, _ = jax.lax.scan(outer, x, ws)
        return c

    def unrolled(x, ws):
        for i in range(To):
            for j in range(Ti):
                x = x * ws[i, j] + 1.0
        return x

    x = jax.ShapeDtypeStruct((256,), jnp.float32)
    ws = jax.ShapeDtypeStruct((To, Ti, 256), jnp.float32)
    off, _ = _cost_official(unrolled, x, ws)
    mine, _ = _cost_mine(fn, x, ws)
    # elementwise flop conventions differ slightly (fma counting); 2x band
    assert mine == pytest.approx(off, rel=1.0)
    assert mine >= 0.5 * To * Ti * 256  # definitely scaled by both trips


def test_collective_wire_bytes_all_reduce():
    """all-reduce ring wire bytes = 2 * size * (n-1)/n per chip."""
    import os
    n = 4
    devs = jax.devices()
    if len(devs) < n:
        pytest.skip("needs >=4 devices (run under dryrun env)")


def test_collective_parse_from_text():
    # synthetic HLO with known collectives
    txt = """
HloModule m, entry_computation_layout={(f32[128]{0})->f32[128]{0}}

ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %ar = f32[128]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %cp = f32[128]{0} copy(%ar)
}
"""
    mc = hlo_cost.analyze_text(txt, n_chips=4)
    # 2 * 512B * 3/4 = 768
    assert mc.coll_breakdown["all-reduce"] == pytest.approx(768.0)


def test_scanned_transformer_flops_close_to_6nd():
    """End-to-end: tiny scanned transformer train step ~ 6*N*D flops."""
    from repro.configs.registry import get_config
    from repro.configs.base import param_count
    from repro.optim import optimizers as opt
    from repro.train import steps
    from repro.data import tokens as dtok

    cfg = get_config("smollm-360m").scaled().with_(
        dtype="float32", param_dtype="float32", loss_chunk=16)
    B, S = 4, 64
    batch = dtok.batch_for_step(cfg, 0, global_batch=B, seq_len=S)
    optimizer = opt.make(cfg.optimizer, opt.cosine_schedule(1e-3, 10, 100))
    state_shapes = steps.state_shape(cfg, optimizer)
    step = steps.build_train_step(cfg, optimizer)
    lowered = jax.jit(step).lower(
        state_shapes, jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch))
    mc = hlo_cost.analyze_text(lowered.compile().as_text())
    n = param_count(cfg)
    model_flops = 6 * n * B * S
    # attention flops + elementwise push it above 6ND; remat/unfused adds more.
    # The old (broken) path was ~num_layers x BELOW 6ND.
    assert mc.flops > 0.5 * model_flops
    assert mc.flops < 12 * model_flops


def test_scan_stacked_outputs_bytes_not_quadratic():
    """A scan stacking per-step outputs (ys) must charge the update region
    per iteration, not the whole stacked buffer (XLA updates in place)."""
    T, N = 64, 1024

    def body(c, w):
        y = c * w
        return c + 1.0, y

    def scanned(x, ws):
        _, ys = jax.lax.scan(body, x, ws)
        return ys

    x = jax.ShapeDtypeStruct((N,), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, N), jnp.float32)
    _, mine_bytes = _cost_mine(scanned, x, ws)
    stacked = T * N * 4
    # per-iter: read w slice + carry + write y slice  ->  O(T*N), not O(T^2*N)
    assert mine_bytes < 12 * stacked, mine_bytes
    assert mine_bytes >= 2 * stacked

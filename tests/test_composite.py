"""Shared-array composite dispatch: bit-exactness + scheduling + billing.

The acceptance property of true sub-array sharing: when resident
programs' S-modes tile the 256-channel array exactly, ONE composite
``pallas_call`` per batch (``interpreter.CompositePlan`` /
``kernels.megakernel.composite_forward``) must serve every member's
frames *bit-identically* to dispatching each member solo — for every
registry program combination tested, for random programs / lane mixes /
S-mode combinations (hypothesis), for ragged and partial batches, and
through the ``ChipServer(shared=True)`` scheduler with fairness and
per-sub-array padding billing preserved.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.chip import interpreter, isa, networks
from repro.serving import ChipServer
from repro.serving.scheduler import plan_shared_groups
from tests.test_fold_pack_property import _random_bn_params, random_program


def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


def _artifact(program, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    return interpreter.fold_params(params, program, packed=True)


def _solo_oracle(program, packed, frames):
    plan = interpreter.compile_plan(program)
    logits, labels = plan.forward(packed, jnp.asarray(frames),
                                  interpret=True)
    return np.asarray(logits), np.asarray(labels)


def _assert_composite_matches_solo(progs, *, batches, seed=0, bb=2, ft=0):
    """Build a composite over ``progs`` and check member-by-member
    bit-exactness vs each member's solo staged forward."""
    arts = {n: _artifact(p, seed=seed + i)
            for i, (n, p) in enumerate(progs.items())}
    cplan, cimage = interpreter.pack_programs(progs, arts)
    frames = {n: _frames(p, b, seed=seed + 10 + i)
              for i, ((n, p), b) in enumerate(zip(progs.items(), batches))}
    logits, labels = cplan.forward(cimage, frames, interpret=True,
                                   bb=bb, ft=ft)
    for i, (n, p) in enumerate(progs.items()):
        ref_logits, ref_labels = _solo_oracle(p, arts[n], frames[n])
        np.testing.assert_array_equal(np.asarray(logits[i]), ref_logits,
                                      err_msg=f"{n} logits")
        np.testing.assert_array_equal(np.asarray(labels[i]), ref_labels,
                                      err_msg=f"{n} labels")


# ---------------------------------------------------------------------------
# 1. Registry combinations: every exact tiling the registry can form
# ---------------------------------------------------------------------------

# (names -> program factory) per combination; ragged member batches on
# purpose.  4xS4 with identical conv chains exercises the grouped
# (stacked sub-array) body; mixed-topology combos exercise the
# per-member body; 2xS2 and S2+2xS4 cover the other exact tilings.
_REGISTRY_COMBOS = {
    "4xS4_grouped": {
        "mnist5": lambda: networks.mnist5(),
        "wake": lambda: networks.mnist5(classes=2),
        "tri": lambda: networks.mnist5(classes=3),
        "five": lambda: networks.mnist5(classes=5),
    },
    "4xS4_mixed_topology": {
        "mnist5": lambda: networks.mnist5(),
        "face_detector": networks.face_detector,
        "cifar9_s4": lambda: networks.cifar9(4),
        "wake": lambda: networks.mnist5(classes=2),
    },
    "2xS2": {
        "cifar9_s2": lambda: networks.cifar9(2),
        "face_angles": networks.face_angles,
    },
    "S2+2xS4": {
        "cifar9_s2": lambda: networks.cifar9(2),
        "mnist5": lambda: networks.mnist5(),
        "face_detector": networks.face_detector,
    },
}
_SLOW_COMBOS = {"2xS2", "S2+2xS4", "4xS4_mixed_topology"}


@pytest.mark.parametrize(
    "combo", [pytest.param(c, marks=pytest.mark.slow) if c in _SLOW_COMBOS
              else c for c in sorted(_REGISTRY_COMBOS)])
def test_composite_bit_exact_on_registry_combos(combo):
    """Composite dispatch == solo dispatch for every registry S-mode
    tiling, with ragged member batches (1..4 frames per member)."""
    progs = {n: f() for n, f in _REGISTRY_COMBOS[combo].items()}
    _assert_composite_matches_solo(progs,
                                   batches=[3, 1, 4, 2][:len(progs)],
                                   seed=hash(combo) % 1000)


def test_composite_f_tiling_is_pure_schedule():
    """Any f-tile size gives identical composite results — tiling is a
    streaming schedule, never a numeric choice."""
    progs = {"a": networks.mnist5(), "b": networks.mnist5(classes=2),
             "c": networks.mnist5(classes=3),
             "d": networks.mnist5(classes=7)}
    arts = {n: _artifact(p, seed=i) for i, (n, p) in enumerate(progs.items())}
    cplan, cimage = interpreter.pack_programs(progs, arts)
    frames = tuple(_frames(p, 3, seed=20 + i)
                   for i, p in enumerate(progs.values()))
    ref = cplan.forward(cimage, frames, interpret=True, bb=2, ft=0)[0]
    for bb, ft in ((1, 32), (3, 32), (2, 33), (8, 64)):
        got = cplan.forward(cimage, frames, interpret=True, bb=bb, ft=ft)[0]
        for r, g in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                          err_msg=f"bb={bb} ft={ft}")


def test_pack_programs_rejects_inexact_tiling():
    """The composite is only valid when sum(256/S) == 256 — the chip
    cannot recombine sub-arrays that don't tile the array."""
    progs = {"a": networks.mnist5(), "b": networks.mnist5(classes=2)}
    arts = {n: _artifact(p) for n, p in progs.items()}
    with pytest.raises(isa.ProgramError, match="tile the array"):
        interpreter.pack_programs(progs, arts)
    three = {"a": networks.mnist5(), "b": networks.mnist5(classes=2),
             "c": networks.cifar9(2), "d": networks.cifar9(2, classes=3)}
    with pytest.raises(isa.ProgramError, match="tile the array"):
        interpreter.pack_programs(
            three, {n: _artifact(p) for n, p in three.items()})


def test_composite_image_packs_members_side_by_side():
    """The composite weight image holds member m's conv words at F rows
    [f_off_m, f_off_m + 256/S_m) — the side-by-side SRAM layout."""
    progs = {"a": networks.mnist5(), "b": networks.mnist5(classes=2),
             "c": networks.mnist5(classes=3), "d": networks.mnist5(classes=5)}
    arts = {n: _artifact(p, seed=i) for i, (n, p) in enumerate(progs.items())}
    cplan, cimage = interpreter.pack_programs(progs, arts)
    assert cimage["cw"].shape[1] == isa.ARRAY_CHANNELS
    off = 0
    for i, (n, p) in enumerate(progs.items()):
        img = interpreter.ensure_image(arts[n], p)
        f = isa.ARRAY_CHANNELS // p.s
        np.testing.assert_array_equal(
            np.asarray(cimage["cw"][:img["cw"].shape[0],
                                    off:off + f, :, :img["cw"].shape[3]]),
            np.asarray(img["cw"]), err_msg=n)
        np.testing.assert_array_equal(
            np.asarray(cimage["ct"][:img["ct"].shape[0], off:off + f]),
            np.asarray(img["ct"]), err_msg=n)
        # member spec carries exactly this offset
        conv_offsets = {st[6] for st in cplan.spec[i] if st[0] == "conv"}
        assert conv_offsets == {off}
        off += f


# ---------------------------------------------------------------------------
# 2. Hypothesis: random programs x random S tilings x ragged batches
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(tiling=st.sampled_from([(2, 2), (2, 4, 4), (4, 4, 4, 4)]),
       seed=st.integers(0, 2 ** 16))
def test_composite_matches_solo_on_random_programs(tiling, seed):
    """Property: random valid member programs (random depths, pooling,
    hidden FCs, IO precisions) under every exact S tiling, with ragged
    per-member batches -> composite == solo, bit-exact per member."""
    progs, arts, frames = {}, {}, {}
    for i, s in enumerate(tiling):
        name = f"p{i}"
        prog = random_program(s, seed + 101 * i)
        params = _random_bn_params(prog, seed + 13 * i)
        progs[name] = prog
        arts[name] = interpreter.fold_params(params, prog, packed=True)
        frames[name] = _frames(prog, 1 + (seed + i) % 5, seed=seed + 29 * i)
    cplan, cimage = interpreter.pack_programs(progs, arts)
    bb = 1 + seed % 4
    ft = (0, 32, 64)[seed % 3]
    logits, labels = cplan.forward(cimage, frames, interpret=True,
                                   bb=bb, ft=ft)
    for i, n in enumerate(progs):
        ref_logits, ref_labels = _solo_oracle(progs[n], arts[n], frames[n])
        np.testing.assert_array_equal(np.asarray(logits[i]), ref_logits,
                                      err_msg=f"{n} (s={progs[n].s})")
        np.testing.assert_array_equal(np.asarray(labels[i]), ref_labels)


# ---------------------------------------------------------------------------
# 3. The shared-array server: scheduling, fairness, billing
# ---------------------------------------------------------------------------

import functools


@functools.lru_cache(maxsize=1)
def _quad():
    """Four S=4 mnist5-family programs — one exact-tiling group.  (A
    cached helper rather than a pytest fixture so the hypothesis
    property below can use it too: the offline hypothesis stub cannot
    inject fixtures into ``@given`` tests.)"""
    progs = {"mnist5": networks.mnist5(),
             "wake": networks.mnist5(classes=2),
             "tri": networks.mnist5(classes=3),
             "five": networks.mnist5(classes=5)}
    arts = {n: _artifact(p, seed=i) for i, (n, p) in enumerate(progs.items())}
    return progs, arts


@pytest.fixture(scope="module")
def quad_setup():
    return _quad()


def test_plan_shared_groups():
    mk = networks.mnist5
    # 4xS4 -> one group; leftover S4 pair -> no group
    progs = {"a": mk(), "b": mk(classes=2), "c": mk(classes=3),
             "d": mk(classes=5), "e": mk(classes=6), "f": mk(classes=7)}
    assert plan_shared_groups(progs) == (("a", "b", "c", "d"),)
    # S2 + 2xS4 packs across modes (widest first)
    mixed = {"s4a": mk(), "s2": networks.cifar9(2), "s4b": mk(classes=2)}
    assert plan_shared_groups(mixed) == (("s2", "s4a", "s4b"),)
    # an S1 program fills the array alone: never a shared group
    solo = {"s1": networks.cifar9(1), "s4": mk()}
    assert plan_shared_groups(solo) == ()


@settings(max_examples=6, deadline=None)
@given(n_frames=st.sampled_from([(5, 5, 5, 5), (7, 1, 0, 3), (1, 1, 1, 1),
                                 (9, 2, 5, 0)]),
       batch=st.integers(2, 4), seed=st.integers(0, 2 ** 16))
def test_shared_server_bit_exact_vs_solo_server(n_frames, batch, seed):
    """Property: over random lane mixes and ragged/partial batches the
    shared server returns the exact (rid, program, label, logits) set of
    the solo server — sub-array sharing changes the schedule, never the
    results."""
    progs, arts = _quad()
    frames = {n: _frames(p, 10, seed=seed + i)
              for i, (n, p) in enumerate(progs.items())}
    runs = {}
    for shared in (False, True):
        server = ChipServer(progs, arts, batch=batch, interpret=True,
                            shared=shared)
        rng_order = list(progs)
        for i in range(max(n_frames)):
            for n, k in zip(rng_order, n_frames):
                if i < k:
                    server.submit(n, frames[n][i])
        res = server.drain()
        runs[shared] = sorted(
            ((r.rid, r.program, r.label, tuple(np.asarray(r.logits)))
             for r in res))
        assert server.queue.pending() == 0
    assert runs[False] == runs[True]


def test_shared_server_utilization_and_billing(quad_setup):
    """A full 4-lane backlog dispatches as composites at utilization 1.0
    with per-sub-array padding billed; an idle member's sub-array burns
    its whole batch (the always-on array never idles)."""
    progs, arts = quad_setup
    server = ChipServer(progs, arts, batch=4, interpret=True, shared=True)
    frames = {n: _frames(p, 4, seed=50 + i)
              for i, (n, p) in enumerate(progs.items())}
    for n in progs:
        server.submit_many(n, frames[n])
    server.drain()
    stats = server.stats()
    assert stats.dispatches == 1 and stats.shared_dispatches == 1
    assert stats.array_utilization == pytest.approx(1.0)
    assert stats.padded == {n: 0 for n in progs}

    # ragged: two lanes backlogged, two idle -> their sub-arrays burn
    server = ChipServer(progs, arts, batch=4, interpret=True, shared=True)
    server.submit_many("mnist5", frames["mnist5"][:3])
    server.submit("wake", frames["wake"][0])
    res = server.drain()
    stats = server.stats()
    assert len(res) == 4
    assert stats.dispatches == 1 and stats.shared_dispatches == 1
    assert stats.padded == {"mnist5": 1, "wake": 3, "tri": 4, "five": 4}
    # utilization only counts sub-arrays doing real work
    assert stats.array_utilization == pytest.approx(0.5)
    # the chip bill sees every burned slot
    assert stats.chip.padded == stats.padded

    # a single backlogged lane falls back to a solo dispatch: no phantom
    # padding billed to the other members
    server = ChipServer(progs, arts, batch=4, interpret=True, shared=True)
    server.submit_many("tri", frames["tri"][:2])
    res = server.drain()
    stats = server.stats()
    assert [r.program for r in res] == ["tri", "tri"]
    assert stats.shared_dispatches == 0
    assert stats.padded == {"mnist5": 0, "wake": 0, "tri": 2, "five": 0}
    assert stats.array_utilization == pytest.approx(0.25)


def test_shared_server_with_prefetch_depth_matches(quad_setup):
    """shared=True composes with depth-k prefetch: identical result
    stream, dispatch indices included."""
    progs, arts = quad_setup
    frames = {n: _frames(p, 6, seed=70 + i)
              for i, (n, p) in enumerate(progs.items())}
    runs = {}
    for depth in (0, 1, 3):
        server = ChipServer(progs, arts, batch=2, interpret=True,
                            shared=True, prefetch=depth)
        for i in range(6):
            for n in progs:
                server.submit(n, frames[n][i])
        res = server.drain()
        runs[depth] = [(r.rid, r.program, r.label, r.dispatch) for r in res]
    assert runs[0] == runs[1] == runs[3]


def test_shared_server_megakernel_solo_members(quad_setup):
    """shared=True + megakernel=True: composite groups use the composite
    kernel; a program outside any group still dispatches through its own
    megakernel — both bit-exact vs the staged oracle."""
    progs, arts = quad_setup
    progs = dict(progs)
    arts = dict(arts)
    progs["owner"] = networks.cifar9(1, classes=2)     # S=1: never grouped
    arts["owner"] = _artifact(progs["owner"], seed=9)
    frames = {n: _frames(p, 3, seed=90 + i)
              for i, (n, p) in enumerate(progs.items())}
    oracle = {n: _solo_oracle(progs[n], arts[n], frames[n])[1]
              for n in progs}
    server = ChipServer(progs, arts, batch=2, interpret=True, shared=True,
                        megakernel=True)
    for n in progs:
        server.submit_many(n, frames[n])
    res = server.drain()
    for n in progs:
        got = [r.label for r in sorted(res, key=lambda r: r.rid)
               if r.program == n]
        np.testing.assert_array_equal(np.array(got), oracle[n], err_msg=n)
    assert server.stats().shared_dispatches > 0

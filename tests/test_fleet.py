"""Serve fleet: failover, migration, warm start + clock-domain pins.

Four property groups:

1. **Clock domain** — regression pins for the clock-injection contract:
   every server-side timestamp (``_host_wall_s``, trace ``t_submit`` /
   ``t_done``) comes from the *injected* clock.  Under a
   :class:`VirtualClock` (which only advances when explicitly slept) any
   leak of ``time.perf_counter()`` shows up as a wall-clock-magnitude
   timestamp; these tests pin all of them to the virtual domain.
2. **Failover** — killing one of >= 2 replicas mid-replay loses zero
   frames, served labels stay bit-exact vs the offline oracle, migrated
   frames keep their per-lane order and serve ahead of anything routed
   to the survivor after the failure.
3. **Billing** — fleet-wide ``billed == served + padded`` (including a
   kill with in-flight dispatches: those frames are honestly re-billed
   by whoever serves them, surfaced as ``refired_frames``).
4. **Warm start** — identical serve configurations share one compiled
   serve fn through :mod:`repro.kernels.cache`; a replacement replica's
   bring-up is a cache hit.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.chip import interpreter, networks
from repro.kernels import cache as warmcache
from repro.serving import (ChipServer, FaultInjector, ServeFleet,
                           VirtualClock, poisson_trace, replay)
from repro.serving.queue import FrameQueue, FrameRequest


def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


@pytest.fixture(scope="module")
def mnist_setup():
    program = networks.mnist5()
    params = interpreter.init_params(jax.random.PRNGKey(3), program)
    packed = interpreter.fold_params(params, program, packed=True)
    frames = _frames(program, 24, seed=11)
    plan = interpreter.compile_plan(program)
    logits, labels = plan.forward(packed, jnp.asarray(frames),
                                  interpret=True)
    return program, packed, frames, np.asarray(labels)


def _fleet(program, packed, clock, **kw):
    kw.setdefault("replicas", 2)
    kw.setdefault("batch", 4)
    return ServeFleet({"mnist5": program}, {"mnist5": packed},
                      interpret=True, clock=clock, sleep=clock.sleep, **kw)


# ---------------------------------------------------------------------------
# 1. Clock-domain pins
# ---------------------------------------------------------------------------

def test_step_wall_time_comes_from_injected_clock(mnist_setup):
    """Regression pin for the server.step() clock fix: with a virtual
    clock that never advances, _host_wall_s must stay exactly 0.0 — any
    direct time.perf_counter() read inside step() would leak a positive
    wall-time delta."""
    program, packed, frames, _ = mnist_setup
    vc = VirtualClock(start=5.0)
    server = ChipServer({"mnist5": program}, {"mnist5": packed},
                        batch=4, interpret=True, clock=vc)
    for f in frames[:6]:
        server.submit("mnist5", f)
    results = server.drain()
    assert len(results) == 6
    assert server._host_wall_s == 0.0
    assert server.stats().host_frames_per_s == 0.0


def test_trace_timestamps_come_from_injected_clock(mnist_setup):
    """Every t_submit / t_done in the latency trace lives in the virtual
    clock's domain (a perf_counter leak would be orders of magnitude
    off the virtual epoch)."""
    program, packed, frames, _ = mnist_setup
    vc = VirtualClock(start=1.0)
    server = ChipServer({"mnist5": program}, {"mnist5": packed},
                        batch=4, interpret=True, clock=vc)
    trace = poisson_trace(("mnist5",), rate=50.0, n=10, seed=7)
    results = replay(server, trace, {"mnist5": frames},
                     clock=vc, sleep=vc.sleep)
    assert results
    recs = server.latency_trace()
    assert recs
    for rec in recs:
        assert 1.0 <= rec["t_submit"] <= vc.now
        assert 1.0 <= rec["t_done"] <= vc.now
        assert rec["latency_ms"] >= 0.0
    assert server._host_wall_s == 0.0


def test_serve_driver_uses_single_injected_clock(capsys):
    """Regression pin for the launch/serve.py clock fix: the LM serving
    driver runs entirely on an injected clock + sleep (previously it
    mixed time.time() with time.perf_counter() across admission pacing
    and the final throughput figure)."""
    from repro.launch import serve as serve_driver
    vc = VirtualClock(start=0.0)
    sleeps = []

    def vsleep(dt):
        sleeps.append(dt)
        vc.sleep(dt)

    serve_driver.main(["--arch", "smollm-360m", "--scaled",
                       "--requests", "3", "--batch", "2",
                       "--prompt-len", "8", "--gen-len", "2",
                       "--rate", "100"],
                      clock=vc, sleep=vsleep)
    out = capsys.readouterr().out
    assert "3 requests" in out
    # paced admission slept on the virtual clock (and never negative)
    assert sleeps and all(dt >= 0 for dt in sleeps)
    assert vc.now == pytest.approx(sum(sleeps))


# ---------------------------------------------------------------------------
# 2. Failover: zero loss, bit-exact, per-lane order
# ---------------------------------------------------------------------------

def test_failover_zero_loss_bit_exact_mid_replay(mnist_setup):
    """Kill one of two replicas mid-replay: every submitted frame is
    served exactly once and every label matches the offline oracle."""
    program, packed, frames, labels = mnist_setup
    vc = VirtualClock()
    inj = FaultInjector("host0", after_served=4)
    fleet = _fleet(program, packed, vc, injector=inj, replace=True)
    trace = poisson_trace(("mnist5",), rate=100.0, n=20, seed=3)
    results = replay(fleet, trace, {"mnist5": frames},
                     clock=vc, sleep=vc.sleep)
    n = len(trace)
    assert sorted(r.rid for r in results) == list(range(n))
    for r in results:
        assert r.label == labels[r.rid % len(frames)]
    st = fleet.stats()
    assert inj.fired
    assert st.failed_replicas == ("host0",)
    assert st.migrated_frames >= 0
    assert st.total_served == n + st.refired_frames


def test_migration_preserves_per_lane_order(mnist_setup):
    """Migrated frames enter the survivor's lane front: they keep their
    own relative order and serve before anything routed to the survivor
    after the failure; the survivor's own frames also stay in order."""
    program, packed, frames, _ = mnist_setup
    vc = VirtualClock()
    fleet = _fleet(program, packed, vc, batch=2, replace=False)
    # blocks of 2: rids 0,1 -> host0; 2,3 -> host1; 4,5 -> host0; 6,7 -> host1
    for f in frames[:8]:
        fleet.submit("mnist5", f)
    first = fleet.step()             # one dispatch on each replica
    assert len(first) == 4
    orphans = fleet.fail("host0")
    migrated = [r.rid for r in orphans["mnist5"]]
    assert migrated == [4, 5]        # host0's queued backlog, in order
    post = [fleet.submit("mnist5", f) for f in frames[8:12]]
    results = fleet.drain()
    served_after = [r.rid for r in results]
    # zero loss: everything not already served comes out of the drain
    assert sorted(served_after) == [4, 5, 6, 7] + post
    # migrated frames first (in order), then the survivor's own queue,
    # then the post-failure admissions
    assert served_after[:2] == [4, 5]
    assert served_after.index(6) < served_after.index(7)
    assert max(served_after.index(r) for r in [4, 5, 6, 7]) < \
        min(served_after.index(r) for r in post)


def test_fail_last_replica_raises(mnist_setup):
    program, packed, frames, _ = mnist_setup
    vc = VirtualClock()
    fleet = _fleet(program, packed, vc, replicas=1, replace=False)
    fleet.submit("mnist5", frames[0])
    with pytest.raises(RuntimeError, match="no survivors"):
        fleet.fail("host0")


def test_requeue_front_order_and_lane_guard():
    q = FrameQueue(["a", "b"])
    q.submit(FrameRequest(rid=10, program="a", frame=None))
    old = [FrameRequest(rid=1, program="a", frame=None),
           FrameRequest(rid=2, program="a", frame=None)]
    q.requeue_front("a", old)
    assert [r.rid for r in q.take("a", 10)] == [1, 2, 10]
    with pytest.raises(ValueError, match="belongs to lane"):
        q.requeue_front("b", old)


# ---------------------------------------------------------------------------
# 3. Billing: billed == served + padded fleet-wide
# ---------------------------------------------------------------------------

def test_fleet_billing_with_padding_and_failure(mnist_setup):
    program, packed, frames, _ = mnist_setup
    vc = VirtualClock()
    inj = FaultInjector("host0", after_served=2)
    # prefetch=1 keeps a dispatch in flight, so the kill aborts real
    # in-flight work and the refired re-bill path is exercised
    fleet = _fleet(program, packed, vc, batch=2, prefetch=1,
                   injector=inj, replace=False)
    for f in frames[:10]:
        fleet.submit("mnist5", f)
    results = fleet.drain()
    assert sorted(r.rid for r in results) == list(range(10))
    st = fleet.stats()
    assert st.billed == st.total_served + sum(st.padded.values())
    assert st.total_served == 10 + st.refired_frames
    assert st.chip.total_frames == st.total_served
    assert st.energy_uj > 0.0
    # the victim's books stay in the fleet bill
    assert "host0" in st.replicas
    dead = st.replicas["host0"]
    assert sum(dead.served.values()) + sum(dead.padded.values()) > 0


# ---------------------------------------------------------------------------
# 4. Warm start
# ---------------------------------------------------------------------------

def test_warm_start_shares_serve_fn(mnist_setup):
    program, packed, _, _ = mnist_setup
    warmcache.invalidate()
    s1 = ChipServer({"mnist5": program}, {"mnist5": packed},
                    batch=4, interpret=True)
    after_one = warmcache.stats()
    assert after_one["misses"] == 1 and after_one["hits"] == 0
    s2 = ChipServer({"mnist5": program}, {"mnist5": packed},
                    batch=4, interpret=True)
    after_two = warmcache.stats()
    assert after_two["hits"] == 1
    assert s2.executor._fns["mnist5"] is s1.executor._fns["mnist5"]
    # opting out bypasses the cache entirely
    s3 = ChipServer({"mnist5": program}, {"mnist5": packed},
                    batch=4, interpret=True, warm_start=False)
    assert warmcache.stats() == after_two
    assert s3.executor._fns["mnist5"] is not s1.executor._fns["mnist5"]


def test_serve_fn_key_schema(mnist_setup):
    program, _, _, _ = mnist_setup
    k1 = warmcache.serve_fn_key((program,), interpret=True)
    assert k1.startswith(f"v{warmcache.SCHEMA}/serve/")
    assert k1 == warmcache.serve_fn_key((program,), interpret=True)
    k2 = warmcache.serve_fn_key((program,), interpret=True, megakernel=True)
    assert k2 != k1
    k3 = warmcache.serve_fn_key((program,), interpret=True, kind="composite")
    assert k3 != k1


def test_replacement_replica_warm_starts(mnist_setup):
    """A replacement spawned after a kill hits the warm-start cache (its
    serve-fn key matches the dead host's) and goes on to serve frames —
    recovery is measurable on the fleet clock."""
    program, packed, frames, labels = mnist_setup
    vc = VirtualClock()
    inj = FaultInjector("host0", after_served=2)
    fleet = _fleet(program, packed, vc, batch=2, injector=inj,
                   replace=True)
    for f in frames[:4]:
        fleet.submit("mnist5", f)
    fleet.drain()
    assert fleet.failed_replicas == ("host0",)
    hits_after_fail = warmcache.stats()["hits"]
    assert hits_after_fail >= 1    # replacement build was (at least) a hit
    # route fresh traffic; the replacement is in rotation and serves
    post = [fleet.submit("mnist5", f) for f in frames[4:12]]
    results = fleet.drain()
    assert sorted(r.rid for r in results) == post
    replacement = [n for n in fleet.live_replicas if n.startswith("host0")]
    assert replacement
    served_by = {n: sum(fleet.replicas[n].stats().served.values())
                 for n in fleet.live_replicas}
    assert served_by[replacement[0]] > 0
    assert fleet.recovery_ms is not None and fleet.recovery_ms >= 0.0
    for r in results:
        assert r.label == labels[r.rid % len(frames)]

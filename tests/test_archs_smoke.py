"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward + one train step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import active_param_count, param_count
from repro.configs.registry import ARCH_IDS, get_config
from repro.data import tokens as dtok
from repro.models import transformer
from repro.optim import optimizers as opt
from repro.train import steps

B, S = 2, 32


def _batch(cfg, key):
    if not cfg.embed_inputs:
        b = dtok.vlm_batch_for_step(cfg, 0, global_batch=B, seq_len=S)
    else:
        b = dtok.batch_for_step(cfg, 0, global_batch=B, seq_len=S)
    return b


# the two MoE giants dominate the suite's wall clock (30s/12s on CPU);
# they run in the slow CI tier, the rest stay in the fast signal
_SLOW_ARCHS = ("jamba-v0.1-52b", "kimi-k2-1t-a32b")


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
             else a for a in ARCH_IDS])
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).scaled().with_(dtype="float32",
                                          param_dtype="float32",
                                          loss_chunk=16)
    key = jax.random.PRNGKey(0)
    batch = _batch(cfg, key)

    # forward
    params = transformer.init_params(key, cfg)
    h, _, aux = transformer.forward(params, cfg, batch, mode="train")
    assert h.shape == (B, S, cfg.d_model)
    logits = transformer.lm_logits(params, cfg, h)
    if cfg.num_codebooks > 1:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one full train step
    optimizer = opt.make(cfg.optimizer, opt.cosine_schedule(1e-3, 10, 100))
    state = steps.create_state(cfg, key, optimizer)
    train_step = jax.jit(steps.build_train_step(cfg, optimizer))
    state, metrics = train_step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    assert int(state["step"]) == 1


@pytest.mark.parametrize("arch", ["smollm-360m", "olmoe-1b-7b", "rwkv6-3b"])
def test_smoke_binary_variant(arch):
    """The paper's technique as a config flag on LM archs."""
    cfg = get_config(arch, quant="binary").scaled().with_(
        dtype="float32", param_dtype="float32", loss_chunk=16,
        quant="binary")
    key = jax.random.PRNGKey(1)
    params = transformer.init_params(key, cfg)
    batch = _batch(cfg, key)
    h, _, _ = transformer.forward(params, cfg, batch, mode="train")
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))


@pytest.mark.parametrize("wm", [1.0, 0.5, 0.25])
def test_width_mult_s_knob(wm):
    """BinarEye S-knob generalization: width_mult scales FFN params ~linearly."""
    cfg = get_config("smollm-360m").with_(width_mult=wm)
    n = param_count(cfg)
    base = param_count(get_config("smollm-360m"))
    if wm < 1.0:
        assert n < base
    key = jax.random.PRNGKey(0)
    small = cfg.scaled().with_(dtype="float32", param_dtype="float32",
                               width_mult=wm)
    params = transformer.init_params(key, small)
    h, _, _ = transformer.forward(params, small,
                                  dtok.batch_for_step(small, 0,
                                                      global_batch=B, seq_len=S))
    assert bool(jnp.all(jnp.isfinite(h)))


def test_param_counts_match_published_sizes():
    expect = {
        "kimi-k2-1t-a32b": (1.03e12, 34e9),
        "olmoe-1b-7b": (6.9e9, 1.3e9),
        "qwen1.5-110b": (111e9, 111e9),
        "jamba-v0.1-52b": (52e9, 12e9),
        "rwkv6-3b": (3.1e9, 3.1e9),
        "smollm-360m": (0.36e9, 0.36e9),
    }
    for arch, (tot, act) in expect.items():
        cfg = get_config(arch)
        assert abs(param_count(cfg) - tot) / tot < 0.10, arch
        assert abs(active_param_count(cfg) - act) / act < 0.15, arch

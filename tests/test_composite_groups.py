"""Per-member-group composite f-tiles + autotune cache schema v2.

The composite kernel accepts one f-tile per *member group* (groups of
different sub-array widths want different schedules); tiling stays a
pure schedule choice — bit-exact for every per-group combination — and
the autotune cache records/resolves the per-group tuple under a
versioned entry key so stale (pre-v2) caches degrade to defaults
instead of mis-steering the new kernel.
"""

import json

import jax
import numpy as np
import pytest

from repro.core.chip import interpreter, networks
from repro.kernels import autotune, ops


def _artifact(program, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    return interpreter.fold_params(params, program, packed=True)


def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    autotune.invalidate()
    yield path
    autotune.invalidate()


def _two_group_composite(seed=0):
    """An S2 + 2xS4 tiling with two member groups: the lone cifar9_s2
    chain and the two shape-identical mnist5-family S4 chains."""
    progs = {"s2": networks.cifar9(2, classes=4),
             "m1": networks.mnist5(),
             "m2": networks.mnist5(classes=2)}
    arts = {n: _artifact(p, seed=seed + i)
            for i, (n, p) in enumerate(progs.items())}
    cplan, cimage = interpreter.pack_programs(progs, arts)
    frames = tuple(_frames(p, 2, seed=seed + 10 + i)
                   for i, p in enumerate(progs.values()))
    return cplan, cimage, frames


@pytest.mark.slow
def test_per_group_ft_is_pure_schedule():
    """A per-group ft tuple gives identical composite results as any
    global ft — per-group tiling is a schedule, never a numeric choice."""
    cplan, cimage, frames = _two_group_composite()
    assert cplan.n_groups == 2
    ref = cplan.forward(cimage, frames, interpret=True, bb=2, ft=0)
    for ftg in ((0, 32), (64, 0), (32, 32)):
        got = cplan.forward(cimage, frames, interpret=True, bb=2, ft=ftg)
        for r, g in zip(ref[0], got[0]):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                          err_msg=f"ftg={ftg}")
        for r, g in zip(ref[1], got[1]):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_per_group_ft_length_validated():
    cplan, cimage, frames = _two_group_composite(seed=7)
    with pytest.raises(ValueError, match="member groups"):
        cplan.forward(cimage, frames, interpret=True, bb=2, ft=(0, 32, 64))


def test_member_groups_exposed_through_ops():
    cplan, _, _ = _two_group_composite(seed=3)
    groups = ops.member_groups(cplan.spec)
    assert len(groups) == 2
    assert sorted(m for g in groups for m in g) == [0, 1, 2]


def test_composite_tiles_resolves_per_group_entry(tmp_cache):
    """A tuned entry carrying ftg resolves to the per-group tuple for
    per_group readers, while the plain reader keeps the global ft; a
    group-count mismatch falls back to the global ft."""
    progs = [networks.mnist5(), networks.mnist5(classes=2)]
    pkey = autotune.composite_key(progs)
    autotune.record("mega", pkey, 4,
                    {"bb": 2, "ft": 32, "ftg": [0, 64], "us": 1.0})
    assert autotune.composite_tiles(progs, 4) == (2, 32)
    assert autotune.composite_tiles(progs, 4, per_group=True,
                                    n_groups=2) == (2, (0, 64))
    assert autotune.composite_tiles(progs, 4, per_group=True,
                                    n_groups=3) == (2, 32)
    # explicit arguments always win, in either form
    assert autotune.composite_tiles(progs, 4, ft=(32, 32),
                                    per_group=True, n_groups=2) == (2, (32, 32))
    assert autotune.composite_tiles(progs, 4, bb=8, ft=0) == (8, 0)


def test_stale_schema_entries_degrade_to_defaults(tmp_cache):
    """Pre-v2 entries (unversioned keys) are invisible to the current
    reader — a stale committed cache is cold, never wrong."""
    program = networks.mnist5()
    pkey = autotune.program_key(program)
    stale_key = f"mega/{pkey}/b8/{autotune.backend_fingerprint()}"
    tmp_cache.write_text(json.dumps({stale_key: {"bb": 99, "ft": 77}}))
    autotune.invalidate()
    defaults = (autotune.DEFAULTS["mega"]["bb"],
                autotune.DEFAULTS["mega"]["ft"])
    assert autotune.mega_tiles(program, 8) == defaults
    # a fresh record coexists with the stale entry and wins
    autotune.record("mega", pkey, 8, {"bb": 4, "ft": 32})
    assert autotune.mega_tiles(program, 8) == (4, 32)
    raw = json.loads(tmp_cache.read_text())
    assert stale_key in raw                      # stale data preserved
    assert any(k.startswith(f"v{autotune.SCHEMA}/") for k in raw)


@pytest.mark.slow
def test_tune_composite_records_per_group(tmp_cache):
    """tune_composite (per_group default) records both the global ft and
    the per-group ftg, and CompositePlan.forward resolves through the
    per-group entry bit-exactly."""
    cplan, cimage, frames = _two_group_composite(seed=11)
    entry = autotune.tune_composite(cplan, cimage, frames,
                                    bb_candidates=(2,),
                                    ft_candidates=(0, 32), iters=1,
                                    interpret=True)
    assert {"bb", "ft", "ftg", "us"} <= set(entry)
    assert len(entry["ftg"]) == cplan.n_groups
    bb, ft = autotune.composite_tiles(cplan.programs, 2, per_group=True,
                                      n_groups=cplan.n_groups)
    assert bb == entry["bb"] and ft == tuple(entry["ftg"])
    ref = cplan.forward(cimage, frames, interpret=True, bb=2, ft=0)
    got = cplan.forward(cimage, frames, interpret=True)    # via cache
    for r, g in zip(ref[0], got[0]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))
